"""Benchmark orchestrator: one module per paper table/figure.

  fig2  — CPU task concurrency distributions (paper Fig. 2)
  fig6  — aging-effect management vs baselines (paper Fig. 6)
  fig7  — yearly embodied carbon reduction (paper Fig. 7)
  fig8  — idle-core utilization / oversubscription (paper Fig. 8)
  refresh — replace-vs-extend fleet-refresh curves per hardware SKU
  kern  — kernel microbenches + TPU roofline occupancy
  (roofline terms per arch x shape come from the dry-run: see
   `python -m repro.launch.dryrun --all --out experiments/dryrun` and
   benchmarks/roofline.py which aggregates them into EXPERIMENTS.md.)

Prints ``name,key=value,...`` CSV lines; JSON persisted to experiments/.
Use --quick for CI-scale runs.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    from benchmarks.common import (add_carbon_model_arg, add_fleet_arg,
                                   add_power_model_arg, add_router_arg,
                                   add_scenario_arg, add_telemetry_arg,
                                   axes_epilog, resolve_carbon_models,
                                   resolve_fleets, resolve_power_models,
                                   resolve_routers, resolve_scenarios,
                                   resolve_telemetry)
    ap = argparse.ArgumentParser(
        epilog=axes_epilog(),
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--quick", action="store_true",
                    help="short traces (CI); full runs match the paper")
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,fig2,fig6,fig7,fig8,kern,"
                    "ablations,refresh")
    add_scenario_arg(ap)
    add_router_arg(ap)
    add_carbon_model_arg(ap)
    add_power_model_arg(ap)
    add_fleet_arg(ap)
    add_telemetry_arg(ap)
    args = ap.parse_args()
    dur = 30.0 if args.quick else 120.0
    only = set(args.only.split(",")) if args.only else None
    scenarios = resolve_scenarios(args)
    routers = resolve_routers(args)
    carbon_models = resolve_carbon_models(args)
    power_models = resolve_power_models(args)
    fleets = resolve_fleets(args)
    telemetry = resolve_telemetry(args)

    def want(name: str) -> bool:
        return only is None or name in only

    from benchmarks import (ablations, fig1_motivation,
                            fig2_task_distribution, fig6_aging_effects,
                            fig7_carbon, fig8_idle_cores, kernel_micro,
                            refresh_planning)

    if want("fig1"):
        fig1_motivation.run()
    if want("fig2"):
        fig2_task_distribution.run(duration_s=dur, scenarios=scenarios)
    if want("fig6"):
        fig6_aging_effects.run(duration_s=dur, scenarios=scenarios,
                               routers=routers)
    if want("fig7"):
        fig7_carbon.run(duration_s=dur, scenarios=scenarios,
                        routers=routers, carbon_models=carbon_models,
                        power_models=power_models, fleets=fleets,
                        telemetry=telemetry)
    if want("fig8"):
        fig8_idle_cores.run(duration_s=dur, scenarios=scenarios,
                            routers=routers)
    if want("refresh"):
        refresh_planning.run(mini=args.quick,
                             carbon_models=carbon_models)
    if want("kern"):
        kernel_micro.run()
    if want("ablations") and not args.quick:
        ablations.run()
    print("benchmarks complete", file=sys.stderr)


if __name__ == "__main__":
    main()
