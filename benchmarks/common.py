"""Shared benchmark helpers: CSV emission + experiment cache."""
from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments")


def emit(name: str, rows: list[dict]) -> None:
    """Print `name,key=value,...` lines and persist JSON."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    for row in rows:
        flat = ",".join(f"{k}={v}" for k, v in row.items())
        print(f"{name},{flat}")
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)


def timed(fn, *args, n: int = 3, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / n
    return out, dt
