"""Shared benchmark helpers: CSV emission, experiment cache, and the
registry-axis CLI flags (--scenario / --router / --carbon-model /
--power-model / --fleet, plus the policy grids the drivers sweep
internally) shared by fig2/fig6/fig7/fig8, with --telemetry riding
along."""
from __future__ import annotations

import argparse
import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments")
DEFAULT_SCENARIOS = ("conversation-poisson",)
DEFAULT_ROUTERS = ("jsq",)
DEFAULT_CARBON_MODELS = ("linear-extension",)
DEFAULT_POWER_MODELS = ("flat-tdp",)
DEFAULT_FLEETS = ("uniform",)


def axes_epilog() -> str:
    """--help epilog enumerating every registered name on all seven
    pluggable axes (policy / scenario / router / carbon / power /
    fault / hardware fleet), built from the live registries so it can
    never go stale again."""
    from repro.carbon import available_carbon_models
    from repro.core.policies import available_policies
    from repro.faults import available_fault_models
    from repro.hardware import available_skus
    from repro.power import available_power_models
    from repro.sim.routing import available_routers
    from repro.workloads import available_scenarios
    rows = (
        ("policy (driver-internal sweeps)", available_policies()),
        ("--scenario", available_scenarios()),
        ("--router", available_routers()),
        ("--carbon-model", available_carbon_models()),
        ("--power-model", available_power_models()),
        ("fault_model (ExperimentConfig.fault_model)",
         available_fault_models()),
        ("--fleet (SKUs; also 'uniform' or 'sku:count+sku:rest' specs)",
         available_skus()),
    )
    lines = ["registry axes (see repro.registry):"]
    for flag, names in rows:
        lines.append(f"  {flag}: {', '.join(names)}")
    lines.append(
        "engines (ExperimentConfig.engine): event (per-task event loop, "
        "bit-exact\n  reference), fleet (vectorized time-stepped "
        "surrogate for 100s of machines x\n  hours+; see repro.sim."
        "fleetsim). The fleet engine's jax backend — like the\n  "
        "event engine's opt-in jax aging settler (FleetAgingSettler("
        "backend=\"jax\"))\n  — settles aging in float32: fast, but "
        "results are NOT bit-exact vs the\n  numpy reference; the "
        "pinned goldens assume numpy.")
    return "\n".join(lines)


def add_scenario_arg(parser: argparse.ArgumentParser) -> None:
    parser.add_argument(
        "--scenario", action="append", default=None, metavar="NAME",
        help="workload scenario for the trace-driven figures "
        f"(fig2/fig6/fig7/fig8); repeatable; default {DEFAULT_SCENARIOS[0]}; "
        "fig1/ablations/kern are scenario-independent. See "
        "repro.workloads.available_scenarios()")


def add_router_arg(parser: argparse.ArgumentParser) -> None:
    parser.add_argument(
        "--router", action="append", default=None, metavar="NAME",
        help="cluster-level request router for the trace-driven figures "
        f"(fig6/fig7/fig8); repeatable; default {DEFAULT_ROUTERS[0]}. See "
        "repro.sim.available_routers()")


def resolve_scenarios(args: argparse.Namespace) -> tuple[str, ...]:
    return tuple(args.scenario) if args.scenario else DEFAULT_SCENARIOS


def resolve_routers(args: argparse.Namespace) -> tuple[str, ...]:
    return tuple(args.router) if getattr(args, "router", None) \
        else DEFAULT_ROUTERS


def add_carbon_model_arg(parser: argparse.ArgumentParser) -> None:
    parser.add_argument(
        "--carbon-model", action="append", default=None, metavar="NAME",
        help="carbon-accounting model for the embodied-carbon figures "
        f"(fig7); repeatable; default {DEFAULT_CARBON_MODELS[0]}. See "
        "repro.carbon.available_carbon_models()")


def resolve_carbon_models(args: argparse.Namespace) -> tuple[str, ...]:
    return tuple(args.carbon_model) if getattr(args, "carbon_model", None) \
        else DEFAULT_CARBON_MODELS


def add_power_model_arg(parser: argparse.ArgumentParser) -> None:
    parser.add_argument(
        "--power-model", action="append", default=None, metavar="NAME",
        help="power model pricing per-core residencies into energy "
        f"(fig7); repeatable; default {DEFAULT_POWER_MODELS[0]}. See "
        "repro.power.available_power_models()")


def resolve_power_models(args: argparse.Namespace) -> tuple[str, ...]:
    return tuple(args.power_model) if getattr(args, "power_model", None) \
        else DEFAULT_POWER_MODELS


def add_fleet_arg(parser: argparse.ArgumentParser) -> None:
    parser.add_argument(
        "--fleet", action="append", default=None, metavar="SPEC",
        help="hardware fleet spec: 'uniform' (bit-exact legacy "
        "default), a SKU name for a whole-fleet SKU, or a mixed spec "
        "like 'xeon-40c:1+epyc-64c:rest'; repeatable; default "
        f"{DEFAULT_FLEETS[0]}. See repro.hardware.available_skus()")


def resolve_fleets(args: argparse.Namespace) -> tuple[str, ...]:
    return tuple(args.fleet) if getattr(args, "fleet", None) \
        else DEFAULT_FLEETS


def add_telemetry_arg(parser: argparse.ArgumentParser) -> None:
    parser.add_argument(
        "--telemetry", nargs="?", const="", default=None, metavar="DIR",
        help="record streaming telemetry during the runs; with DIR, "
        "export JSONL events / Chrome trace / series / Prometheus "
        "snapshot per experiment under DIR (see repro.telemetry)")


def resolve_telemetry(args: argparse.Namespace) -> dict | None:
    """`telemetry_opts` dict for `ExperimentConfig`, or None when the
    flag was absent (telemetry off)."""
    v = getattr(args, "telemetry", None)
    if v is None:
        return None
    return {"export_dir": v} if v else {}


def _axes_parser(description: str | None) -> argparse.ArgumentParser:
    return argparse.ArgumentParser(
        description=description, epilog=axes_epilog(),
        formatter_class=argparse.RawDescriptionHelpFormatter)


def parse_scenarios(description: str | None = None) -> tuple[str, ...]:
    """One-stop argparse for the fig drivers' `__main__` blocks."""
    ap = _axes_parser(description)
    add_scenario_arg(ap)
    return resolve_scenarios(ap.parse_args())


def parse_axes(description: str | None = None,
               carbon: bool = False, power: bool = False,
               fleet: bool = False, telemetry: bool = False) -> tuple:
    """argparse for drivers that sweep scenarios and routers; with
    `carbon=True` / `power=True` / `fleet=True` those axes join the
    returned tuple (in that order), and `telemetry=True` appends the
    resolved telemetry opts dict (or None)."""
    ap = _axes_parser(description)
    add_scenario_arg(ap)
    add_router_arg(ap)
    if carbon:
        add_carbon_model_arg(ap)
    if power:
        add_power_model_arg(ap)
    if fleet:
        add_fleet_arg(ap)
    if telemetry:
        add_telemetry_arg(ap)
    args = ap.parse_args()
    axes = (resolve_scenarios(args), resolve_routers(args))
    axes += ((resolve_carbon_models(args),) if carbon else ())
    axes += ((resolve_power_models(args),) if power else ())
    axes += ((resolve_fleets(args),) if fleet else ())
    return axes + ((resolve_telemetry(args),) if telemetry else ())


def emit(name: str, rows: list[dict]) -> None:
    """Print `name,key=value,...` lines and persist JSON."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    for row in rows:
        flat = ",".join(f"{k}={v}" for k, v in row.items())
        print(f"{name},{flat}")
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as f:
        json.dump(rows, f, indent=1, default=str)


def timed(fn, *args, n: int = 3, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / n
    return out, dt
