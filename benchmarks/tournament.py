"""Standing robustness tournament: policy x scenario x fault model.

Every core-management policy runs the same workloads under the same
injected faults (identical silicon, identical fault RNG streams), and
is scored on how gracefully it degrades: availability, tail latency,
total yearly carbon, and *regret* — the carbon gap to the aging-greedy
oracle run under exactly the same faults. The oracle maps every task to
the least-aged core with full observability, so regret isolates how
much of a policy's fault exposure is avoidable by aging awareness
alone.

    PYTHONPATH=src python benchmarks/tournament.py            # full
    PYTHONPATH=src python benchmarks/tournament.py --mini     # CI smoke

Emits a per-(scenario, fault model) text table plus a JSON artifact
(`experiments/tournament.json`, or `tournament_mini.json` with --mini)
via the shared benchmark emitter. The event engine is used throughout —
fault experiments at fleet scale are surrogate estimates (see
`repro.sim.fleetsim`), and the tournament is the reference scoreboard.
"""
from __future__ import annotations

import argparse
import sys

from benchmarks import common
from repro.sim import ExperimentConfig, run_policy_sweep

ORACLE = "aging-greedy"
POLICIES = ("linux", "least-aged", "proposed")

#: (fault model, opts) grid for the full tournament — calibrated so
#: every model actually fires at the default 60 s horizon.
FAULT_SPECS = (
    ("none", {}),
    ("guardband", {"margin": 0.012}),
    ("machine-crash", {"mttf_s": 400.0, "reboot_s": 30.0}),
    ("transient-stall", {}),
)

#: mini-grid variant: small fleet, short horizon, rates bumped so the
#: CI smoke still observes failures/crashes/stalls.
MINI_FAULT_SPECS = (
    ("none", {}),
    ("guardband", {"margin": 0.010}),
    ("machine-crash", {"mttf_s": 15.0, "reboot_s": 5.0}),
    ("transient-stall", {"rate_per_s": 0.2}),
)

COLUMNS = ("availability", "p99_latency_s", "fleet_yearly_total_kgco2eq",
           "regret_kgco2eq", "core_failures", "machine_crashes", "stalls",
           "retries", "failed_requests", "completed")


def run_tournament(cfg: ExperimentConfig, scenarios, fault_specs,
                   policies=POLICIES) -> list[dict]:
    """One sweep per fault spec (so each model carries its own opts);
    the oracle rides in every sweep for the regret column."""
    rows: list[dict] = []
    for fm, opts in fault_specs:
        f_cfg = cfg if fm == cfg.fault_model else \
            cfg.with_fault_model(fm, **opts)
        sweep = run_policy_sweep(f_cfg, policies=policies + (ORACLE,),
                                 scenarios=tuple(scenarios))
        for sc in scenarios:
            oracle = sweep[(ORACLE, sc)]
            for policy in policies + (ORACLE,):
                r = sweep[(policy, sc)]
                rows.append({
                    "policy": policy,
                    "scenario": sc,
                    "fault_model": fm,
                    "availability": round(r.availability, 6),
                    "p99_latency_s": round(r.p99_latency_s, 4),
                    "fleet_yearly_total_kgco2eq":
                        round(r.fleet_yearly_total_kgco2eq, 4),
                    "regret_kgco2eq":
                        round(r.fleet_yearly_total_kgco2eq
                              - oracle.fleet_yearly_total_kgco2eq, 4),
                    "core_failures": r.core_failures,
                    "machine_crashes": r.machine_crashes,
                    "stalls": r.stalls,
                    "retries": r.retries,
                    "failed_requests": r.failed_requests,
                    "completed": r.completed,
                    "submitted": r.submitted,
                    "config_hash": r.provenance.config_hash,
                })
    return rows


def print_tables(rows: list[dict]) -> None:
    """Grouped text tables, one per (scenario, fault model) cell."""
    groups: dict[tuple, list[dict]] = {}
    for row in rows:
        groups.setdefault((row["scenario"], row["fault_model"]),
                          []).append(row)
    hdr = ("policy", *COLUMNS)
    for (sc, fm), grp in groups.items():
        print(f"\n== scenario={sc} fault_model={fm} ==")
        widths = [max(len(h), 12) for h in hdr]
        print("  ".join(h.ljust(w) for h, w in zip(hdr, widths)))
        for row in sorted(grp, key=lambda r: r["policy"]):
            cells = [str(row["policy"])] + [str(row[c]) for c in COLUMNS]
            print("  ".join(c.ljust(w) for c, w in zip(cells, widths)))


def check_rows(rows: list[dict]) -> list[str]:
    """Structural invariants the CI smoke asserts on the mini-grid."""
    problems = []
    by_cell: dict[tuple, dict[str, dict]] = {}
    for row in rows:
        by_cell.setdefault((row["scenario"], row["fault_model"]),
                           {})[row["policy"]] = row
    for (sc, fm), cell in by_cell.items():
        for policy, row in cell.items():
            if not (0.0 <= row["availability"] <= 1.0):
                problems.append(f"{policy}/{sc}/{fm}: availability "
                                f"{row['availability']} out of range")
            if fm == "none" and row["availability"] != 1.0:
                problems.append(f"{policy}/{sc}/none: expected perfect "
                                f"availability")
        if ORACLE in cell and abs(cell[ORACLE]["regret_kgco2eq"]) > 1e-9:
            problems.append(f"{sc}/{fm}: oracle regret must be zero")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        epilog=common.axes_epilog(),
        formatter_class=argparse.RawDescriptionHelpFormatter)
    common.add_scenario_arg(ap)
    common.add_fleet_arg(ap)
    ap.add_argument("--mini", action="store_true",
                    help="CI mini-grid: 1+2-machine fleet, 30 s horizon, "
                    "fault opts tuned to fire at that scale")
    ap.add_argument("--duration", type=float, default=None,
                    help="override horizon seconds")
    ap.add_argument("--seed", type=int, default=3)
    args = ap.parse_args()
    scenarios = common.resolve_scenarios(args)
    fleets = common.resolve_fleets(args)

    if args.mini:
        cfg = ExperimentConfig(duration_s=args.duration or 30.0,
                               n_prompt=1, n_token=2, rate_rps=8.0,
                               seed=args.seed)
        specs = MINI_FAULT_SPECS
    else:
        cfg = ExperimentConfig(duration_s=args.duration or 60.0,
                               seed=args.seed)
        specs = FAULT_SPECS
    if fleets != ("uniform",):
        if len(fleets) != 1:
            ap.error("--fleet takes a single spec for the tournament "
                     "(the scoreboard compares policies, not fleets)")
        cfg = cfg.with_fleet(fleets[0])

    rows = run_tournament(cfg, scenarios, specs)
    print_tables(rows)
    common.emit("tournament_mini" if args.mini else "tournament", rows)
    problems = check_rows(rows)
    if problems:
        print("\ntournament invariant violations:", file=sys.stderr)
        for p in problems:
            print(f"  {p}", file=sys.stderr)
        return 1
    print(f"\ntournament OK: {len(rows)} rows across "
          f"{len(scenarios)} scenario(s) x {len(specs)} fault model(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
