"""Paper Fig. 8: utilization of available cores — distribution of
normalized idle CPU cores (positive = underutilization, negative =
oversubscription). Paper: proposed is >=77% better at p90 and keeps
oversubscription above -0.1 at p1.

`--scenario` (repeatable) runs the same policy sweep under additional
workload scenarios — flash crowds and MMPP bursts are exactly the loads
that stress the oversubscription guarantee (idle_p1 >= -0.1).
`--router` (repeatable) adds the cluster-routing axis: aging-aware
routing must not trade the idle-core guarantee away.
"""
from __future__ import annotations

from repro.sim import DEFAULT_SWEEP, ExperimentConfig, run_policy_sweep

from benchmarks.common import (DEFAULT_ROUTERS, DEFAULT_SCENARIOS, emit,
                               parse_axes)


def run(duration_s: float = 120.0, rates=(40, 100),
        core_counts=(40, 80), policies=DEFAULT_SWEEP,
        scenarios=DEFAULT_SCENARIOS, routers=DEFAULT_ROUTERS) -> list[dict]:
    rows = []
    for scenario in scenarios:
        for router in routers:
            for cores in core_counts:
                for rate in rates:
                    res = run_policy_sweep(
                        ExperimentConfig(num_cores=cores, rate_rps=rate,
                                         duration_s=duration_s, seed=1,
                                         scenario=scenario, router=router),
                        policies=policies)
                    p90_linux = res["linux"].idle_norm_percentiles[90]
                    for name, m in res.items():
                        pct = m.idle_norm_percentiles
                        rows.append({
                            "scenario": m.scenario,
                            "router": m.router,
                            "cores": cores,
                            "rate_rps": rate,
                            "policy": name,
                            "idle_p1": round(pct[1], 4),
                            "idle_p50": round(pct[50], 4),
                            "idle_p90": round(pct[90], 4),
                            "underutil_reduction_vs_linux_pct": round(
                                100 * (1 - pct[90] / max(p90_linux, 1e-9)),
                                2),
                            "oversub_below_10pct": bool(pct[1] >= -0.1),
                            "p99_latency_s": round(m.p99_latency_s, 2),
                        })
    emit("fig8_idle_cores", rows)
    return rows


if __name__ == "__main__":
    scenarios, routers = parse_axes(__doc__)
    run(scenarios=scenarios, routers=routers)
