"""Kernel microbenchmarks: wall-time of the pure-jnp reference formulation
on CPU (the Pallas kernels themselves target TPU; interpret mode is a
correctness harness, not a performance proxy) + analytic kernel roofline
occupancy for the TPU target."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.aging_update import ops as aging_ops
from repro.core.aging import DEFAULT_PARAMS
from repro.launch.mesh import HBM_BW, PEAK_BF16_FLOPS
from benchmarks.common import emit, timed


def run() -> list[dict]:
    rows = []
    key = jax.random.key(0)

    # flash-attention ref (per-device prefill tile): B=1 H=8 S=2048 D=128
    from repro.kernels.flash_attention.ref import flash_attention_ref
    b, h, s, d = 1, 8, 2048, 128
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, h, s, d), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, h, s, d), jnp.bfloat16)
    fn = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v))
    _, dt = timed(lambda: jax.block_until_ready(fn(q, k, v)))
    flops = 4 * b * h * s * s * d
    rows.append({"kernel": "flash_attention_ref_cpu",
                 "us_per_call": round(dt * 1e6, 1),
                 "tpu_roofline_s": flops / PEAK_BF16_FLOPS})

    # decode-attention ref: B=8 H=32 S=32768 D=128 (memory-bound)
    from repro.kernels.decode_attention.ref import decode_attention_ref_explicit
    b, h, hkv, s, d = 8, 32, 8, 8192, 128
    q1 = jax.random.normal(ks[0], (b, h, d), jnp.bfloat16)
    kc = jax.random.normal(ks[1], (b, s, hkv, d), jnp.bfloat16)
    vc = jax.random.normal(ks[2], (b, s, hkv, d), jnp.bfloat16)
    pos = jnp.full((b,), s, jnp.int32)
    fn = jax.jit(lambda q, k, v, p: decode_attention_ref_explicit(q, k, v, p))
    _, dt = timed(lambda: jax.block_until_ready(fn(q1, kc, vc, pos)))
    cache_bytes = 2 * b * s * hkv * d * 2
    rows.append({"kernel": "decode_attention_ref_cpu",
                 "us_per_call": round(dt * 1e6, 1),
                 "tpu_roofline_s": cache_bytes / HBM_BW})

    # ssd ref vs chunked on CPU
    from repro.models.mamba2 import ssd_chunked, ssd_reference
    b, l, h, p, n = 2, 2048, 8, 64, 128
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, l, h, p), jnp.bfloat16)
    dts = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a_log = jax.random.normal(ks[2], (h,)) * 0.5
    bb = jax.random.normal(ks[3], (b, l, n), jnp.bfloat16)
    cc = jax.random.normal(ks[4], (b, l, n), jnp.bfloat16)
    fn_seq = jax.jit(lambda *a: ssd_reference(*a)[0])
    fn_chk = jax.jit(lambda *a: ssd_chunked(*a, chunk=256)[0])
    _, dt_seq = timed(lambda: jax.block_until_ready(fn_seq(x, dts, a_log, bb, cc)))
    _, dt_chk = timed(lambda: jax.block_until_ready(fn_chk(x, dts, a_log, bb, cc)))
    rows.append({"kernel": "ssd_sequential_cpu", "us_per_call": round(dt_seq * 1e6, 1)})
    rows.append({"kernel": "ssd_chunked_cpu", "us_per_call": round(dt_chk * 1e6, 1),
                 "speedup_vs_sequential": round(dt_seq / dt_chk, 2)})

    # aging update: fleet of 22 machines x 80 cores
    import numpy as np
    ncores = 22 * 80
    rng = np.random.default_rng(0)
    dvth = jnp.asarray(rng.uniform(0, 0.05, ncores), jnp.float32)
    temp = jnp.asarray(rng.choice([48.0, 51.08, 54.0], ncores), jnp.float32)
    stress = jnp.asarray(rng.choice([0.0, 1.0], ncores), jnp.float32)
    tau = jnp.asarray(rng.uniform(0, 1e5, ncores), jnp.float32)
    fn = jax.jit(lambda *a: aging_ops.advance_fleet(*a, DEFAULT_PARAMS,
                                                    use_kernel=False))
    _, dt = timed(lambda: jax.block_until_ready(fn(dvth, temp, stress, tau)))
    rows.append({"kernel": "aging_update_fleet_cpu",
                 "us_per_call": round(dt * 1e6, 1), "cores": ncores})

    emit("kernel_micro", rows)
    return rows


if __name__ == "__main__":
    run()
