"""Paper Fig. 1: carbon footprint composition of an inference server under
energy sources of decreasing carbon intensity — shows CPU embodied
becoming dominant, which motivates the whole paper. Includes the
post-technique row (CPU life extended by the measured p99 factor).

Footprints come from the `operational-embodied` model of the pluggable
`repro.carbon` subsystem, one constant-intensity signal per energy
source."""
from __future__ import annotations

from repro.carbon import get_carbon_model

from benchmarks.common import emit

# gCO2/kWh: coal, gas, world-avg grid, solar, wind/hydro/nuclear
INTENSITIES = (820.0, 490.0, 436.0, 41.0, 12.0)


def run(extension_factor: float = 1.6) -> list[dict]:
    rows = []
    for ci in INTENSITIES:
        model = get_carbon_model(
            "operational-embodied", intensity="constant",
            intensity_opts={"value_g_per_kwh": ci})
        # deg_ref == deg_technique -> extension 1.0 (stock refresh cycle);
        # the technique row prices the same server with the CPU kept
        # alive `extension_factor` times longer.
        base = model.footprint(1.0, 1.0)
        ext = model.footprint(extension_factor, 1.0)
        rows.append({
            "carbon_intensity_g_kwh": ci,
            "operational_kg": round(base.operational_kg, 1),
            "cpu_embodied_kg": round(base.cpu_embodied_kg, 1),
            "gpu_embodied_kg": round(base.gpu_embodied_kg, 1),
            "cpu_embodied_frac_of_embodied": round(
                base.cpu_embodied_kg / base.embodied_kg, 3),
            "cpu_embodied_kg_with_technique": round(
                ext.cpu_embodied_kg, 1),
        })
    emit("fig1_motivation", rows)
    return rows


if __name__ == "__main__":
    run()
