"""§Perf: compare baseline vs optimized dry-run records side-by-side.

  PYTHONPATH=src python -m benchmarks.perf_compare \
      [--base experiments/dryrun] [--opt experiments/dryrun_opt]

Emits a markdown table of the three roofline terms before/after and the
delta on each pair's dominant term (the hillclimb verdict input).
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_dir(d: str) -> dict:
    out = {}
    for fn in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(fn))
        if r.get("mesh") != "16x16":
            continue
        out[(r["arch"], r["shape"])] = r
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--base", default="experiments/dryrun")
    ap.add_argument("--opt", default="experiments/dryrun_opt")
    args = ap.parse_args()
    base = load_dir(args.base)
    opt = load_dir(args.opt)
    keys = sorted(set(base) & set(opt))
    if not keys:
        print("no overlapping records")
        return
    print("| arch | shape | term | baseline s | optimized s | delta |")
    print("|---|---|---|---|---|---|")
    for k in keys:
        b, o = base[k], opt[k]
        dom = b["bottleneck"]
        for term in ("compute", "memory", "collective"):
            tb = b["roofline_s"][term]
            to = o["roofline_s"][term]
            mark = " **<-dom**" if term == dom else ""
            delta = (1 - to / tb) * 100 if tb else 0.0
            print(f"| {k[0]} | {k[1]} | {term}{mark} | {tb:.3e} | "
                  f"{to:.3e} | {delta:+.1f}% |")
        pb = b["per_device"]["peak_bytes"] / 1e9
        po = o["per_device"]["peak_bytes"] / 1e9
        print(f"| {k[0]} | {k[1]} | peak GB | {pb:.2f} | {po:.2f} | "
              f"{(1 - po / pb) * 100:+.1f}% |")


if __name__ == "__main__":
    main()
