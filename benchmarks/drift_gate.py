"""CI drift gate: run the default mini-grid and diff it against the
committed golden `SweepResult` — fail loudly on silent metric drift.

    PYTHONPATH=src python benchmarks/drift_gate.py             # check
    PYTHONPATH=src python benchmarks/drift_gate.py --update    # re-pin

The mini-grid is small on purpose (2 policies x 2 routers, 8 s @ 40
rps) — it exists to catch *unintended* numeric drift between commits,
not to benchmark. Every scalar `ExperimentResult` field in the grid is
compared via `SweepResult.diff_scalars`; fields are tolerance-tagged in
`TOLERANCES` (relative), everything untagged must match exactly
(including `config_hash`, so an `ExperimentConfig` field addition —
which changes every fingerprint — trips the gate by design: re-pin
with `--update` and say why in the commit).

Exit status: 0 = no drift, 1 = drift (diff printed), 2 = golden
missing (run `--update` once and commit the file).
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.sim import ExperimentConfig, SweepResult, run_policy_sweep

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "..",
                           "experiments", "golden_minigrid.json")

#: per-field relative tolerances; untagged fields must match exactly.
#: The simulator is deterministic, so these are 0.0 today — the tags
#: exist so a field that legitimately picks up platform jitter (e.g. a
#: future wall-time-derived scalar) can be loosened without weakening
#: the exact check on everything else.
TOLERANCES: dict[str, float] = {}


def mini_grid_config() -> ExperimentConfig:
    return ExperimentConfig(duration_s=8.0, rate_rps=40.0, seed=0)


def run_mini_grid() -> SweepResult:
    return run_policy_sweep(mini_grid_config(),
                            policies=("linux", "proposed"),
                            routers=("jsq", "round-robin"))


def filtered_diff(current: SweepResult,
                  golden: SweepResult) -> dict:
    """`diff_scalars` minus differences inside their field's tagged
    tolerance."""
    raw = current.diff_scalars(golden, rel_tol=0.0)
    out = {}
    for key, fields in raw.items():
        kept = {}
        for field, (a, b) in fields.items():
            tol = TOLERANCES.get(field, 0.0)
            if (tol and isinstance(a, float) and isinstance(b, float)
                    and b and abs(a - b) <= tol * abs(b)):
                continue
            kept[field] = (a, b)
        if kept:
            out[key] = kept
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--update", action="store_true",
                    help="re-capture the golden mini-grid instead of "
                    "checking against it")
    ap.add_argument("--golden", default=GOLDEN_PATH,
                    help="golden SweepResult path")
    args = ap.parse_args()

    current = run_mini_grid()
    if args.update:
        os.makedirs(os.path.dirname(args.golden), exist_ok=True)
        current.save(args.golden)
        print(f"golden mini-grid re-pinned: "
              f"{os.path.normpath(args.golden)} "
              f"({len(current)} cells)")
        return 0

    if not os.path.exists(args.golden):
        print(f"drift gate: golden missing at "
              f"{os.path.normpath(args.golden)} — run with --update "
              f"and commit the file", file=sys.stderr)
        return 2

    golden = SweepResult.load(args.golden)
    diff = filtered_diff(current, golden)
    if not diff:
        print(f"drift gate: {len(current)} cells match the golden "
              f"(no metric drift)")
        return 0
    print("drift gate: METRIC DRIFT vs committed golden:",
          file=sys.stderr)
    for key, fields in diff.items():
        for field, (cur, gold) in fields.items():
            print(f"  {key!r} {field}: current={cur!r} "
                  f"golden={gold!r}", file=sys.stderr)
    print(f"({sum(len(f) for f in diff.values())} field(s) across "
          f"{len(diff)} cell(s); if intentional, re-pin with "
          f"--update and explain in the commit)", file=sys.stderr)
    return 1


if __name__ == "__main__":
    sys.exit(main())
