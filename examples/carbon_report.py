"""Reproduce the paper's headline numbers end-to-end and print a report:
37.67% yearly embodied carbon reduction (p99), 77% less underutilization,
<10% oversubscription.

  PYTHONPATH=src python examples/carbon_report.py [--duration 300]
"""
import argparse

from repro.sim import ExperimentConfig, carbon_comparison, run_policy_sweep


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--rate", type=float, default=70.0)
    ap.add_argument("--cores", type=int, default=40)
    ap.add_argument("--router", default="jsq",
                    help="cluster request router (see "
                    "repro.sim.available_routers())")
    args = ap.parse_args()

    res = run_policy_sweep(ExperimentConfig(
        num_cores=args.cores, rate_rps=args.rate,
        duration_s=args.duration, seed=1, router=args.router))
    linux, proposed = res["linux"], res["proposed"]

    print(f"cluster: 22 machines (5 prompt + 17 token), {args.cores}-core "
          f"VMs, {args.rate} req/s, {args.duration:.0f}s Azure-like trace\n")
    print(f"{'metric':44s} {'paper':>10s} {'ours':>10s}")
    est99 = carbon_comparison(linux, proposed, 99)
    est50 = carbon_comparison(linux, proposed, 50)
    print(f"{'yearly embodied carbon reduction (p99)':44s} "
          f"{'37.67%':>10s} {100*est99.reduction_frac:>9.2f}%")
    print(f"{'yearly embodied carbon reduction (p50)':44s} "
          f"{'49.01%':>10s} {100*est50.reduction_frac:>9.2f}%")
    underutil = 100 * (1 - proposed.idle_norm_percentiles[90]
                       / max(linux.idle_norm_percentiles[90], 1e-9))
    print(f"{'CPU underutilization reduction (p90)':44s} "
          f"{'>=77%':>10s} {underutil:>9.1f}%")
    print(f"{'oversubscription bound (p1 idle norm)':44s} "
          f"{'>-0.1':>10s} {proposed.idle_norm_percentiles[1]:>10.3f}")
    lat = 100 * (proposed.p99_latency_s / linux.p99_latency_s - 1)
    print(f"{'service quality impact (p99 latency)':44s} "
          f"{'<10%':>10s} {lat:>+9.2f}%")
    print(f"\nrouter: {args.router} — fleet degradation CV "
          f"{proposed.fleet_degradation_cv:.4f}, fleet yearly embodied "
          f"{proposed.fleet_yearly_kgco2eq:.1f} kgCO2eq")


if __name__ == "__main__":
    main()
