"""Reproduce the paper's headline numbers end-to-end and print a report:
37.67% yearly embodied carbon reduction (p99), 77% less underutilization,
<10% oversubscription.

  PYTHONPATH=src python examples/carbon_report.py [--duration 300]
      [--carbon-model reliability-threshold] [--power-model minmax-linear]
      [--save sweep.json]

`--carbon-model` re-prices the aging data under any registered
`repro.carbon` model; `--power-model` prices per-core state residencies
into measured energy/operational carbon under any registered
`repro.power` model; `--save` persists the whole sweep as a
`SweepResult` JSON that `repro.sim.SweepResult.load` restores
losslessly (provenance included) for cross-run diffs.
"""
import argparse

from repro.carbon import get_carbon_model
from repro.carbon.models import HOURS_PER_YEAR
from repro.sim import ExperimentConfig, carbon_comparison, run_policy_sweep


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--rate", type=float, default=70.0)
    ap.add_argument("--cores", type=int, default=40)
    ap.add_argument("--router", default="jsq",
                    help="cluster request router (see "
                    "repro.sim.available_routers())")
    ap.add_argument("--carbon-model", default="linear-extension",
                    help="carbon-accounting model (see "
                    "repro.carbon.available_carbon_models())")
    ap.add_argument("--power-model", default="flat-tdp",
                    help="power model pricing per-core residencies into "
                    "energy (see repro.power.available_power_models())")
    ap.add_argument("--intensity", type=float, default=436.0,
                    help="grid carbon intensity [gCO2eq/kWh] for the "
                    "operational+embodied footprint line")
    ap.add_argument("--save", default=None, metavar="PATH",
                    help="write the sweep as a SweepResult JSON")
    ap.add_argument("--telemetry", nargs="?", const="", default=None,
                    metavar="DIR",
                    help="record streaming telemetry; with DIR, export "
                    "JSONL events / Chrome trace / series / Prometheus "
                    "snapshot per policy run under DIR "
                    "(see repro.telemetry)")
    args = ap.parse_args()

    cfg = ExperimentConfig(
        num_cores=args.cores, rate_rps=args.rate,
        duration_s=args.duration, seed=1, router=args.router,
        carbon_model=args.carbon_model, power_model=args.power_model)
    if args.telemetry is not None:
        cfg = cfg.with_telemetry(
            **({"export_dir": args.telemetry} if args.telemetry else {}))
    res = run_policy_sweep(cfg)
    linux, proposed = res["linux"], res["proposed"]

    print(f"cluster: 22 machines (5 prompt + 17 token), {args.cores}-core "
          f"VMs, {args.rate} req/s, {args.duration:.0f}s Azure-like trace\n")
    print(f"{'metric':44s} {'paper':>10s} {'ours':>10s}")
    est99 = carbon_comparison(linux, proposed, 99)
    est50 = carbon_comparison(linux, proposed, 50)
    print(f"{'yearly embodied carbon reduction (p99)':44s} "
          f"{'37.67%':>10s} {100*est99.reduction_frac:>9.2f}%")
    print(f"{'yearly embodied carbon reduction (p50)':44s} "
          f"{'49.01%':>10s} {100*est50.reduction_frac:>9.2f}%")
    underutil = 100 * (1 - proposed.idle_norm_percentiles[90]
                       / max(linux.idle_norm_percentiles[90], 1e-9))
    print(f"{'CPU underutilization reduction (p90)':44s} "
          f"{'>=77%':>10s} {underutil:>9.1f}%")
    print(f"{'oversubscription bound (p1 idle norm)':44s} "
          f"{'>-0.1':>10s} {proposed.idle_norm_percentiles[1]:>10.3f}")
    lat = 100 * (proposed.p99_latency_s / linux.p99_latency_s - 1)
    print(f"{'service quality impact (p99 latency)':44s} "
          f"{'<10%':>10s} {lat:>+9.2f}%")
    print(f"\nrouter: {args.router} — fleet degradation CV "
          f"{proposed.fleet_degradation_cv:.4f}, fleet yearly embodied "
          f"{proposed.fleet_yearly_kgco2eq:.1f} kgCO2eq "
          f"[{args.carbon_model}]")
    yearly_kwh = proposed.mean_machine_power_w * HOURS_PER_YEAR / 1000.0
    print(f"power: {args.power_model} — fleet horizon energy "
          f"{proposed.fleet_energy_kwh:.4f} kWh (mean machine draw "
          f"{proposed.mean_machine_power_w:.0f} W), fleet yearly "
          f"operational {proposed.fleet_yearly_operational_kgco2eq:.1f} "
          f"kgCO2eq, total {proposed.fleet_yearly_total_kgco2eq:.1f}")

    deg_l = linux.mean_degradation_percentiles[99]
    deg_p = proposed.mean_degradation_percentiles[99]
    fp = get_carbon_model(
        "operational-embodied",
        intensity="constant",
        intensity_opts={"value_g_per_kwh": args.intensity},
        lifetime_model=args.carbon_model,
    ).footprint(deg_l, deg_p, energy_kwh_per_year=yearly_kwh)
    print(f"per-server total @ {args.intensity:.0f} gCO2/kWh: "
          f"{fp.total_kg:.0f} kgCO2eq/yr (operational "
          f"{fp.operational_kg:.0f}, CPU embodied {fp.cpu_embodied_kg:.1f}, "
          f"accel embodied {fp.gpu_embodied_kg:.1f}; embodied share "
          f"{100*fp.embodied_frac:.1f}%)")

    if args.telemetry is not None:
        s = proposed.telemetry_summary or {}
        kinds = s.get("event_kinds", {})
        print(f"\ntelemetry: {s.get('events', 0)} events "
              f"({', '.join(f'{k}:{v}' for k, v in kinds.items())}), "
              f"{len(s.get('series', {}))} series, "
              f"{len(s.get('timelines', {}))} timelines")
        for surface, path in (s.get("export") or {}).items():
            print(f"  {surface}: {path}")

    if args.save:
        res.save(args.save)
        print(f"\nsweep saved to {args.save} "
              f"(SweepResult.load round-trips it, provenance included)")


if __name__ == "__main__":
    main()
