"""Train a small decoder on the synthetic Markov corpus until the loss
visibly drops — exercises the full substrate (data pipeline -> model ->
AdamW -> checkpointing). A ~20M-param model trains in minutes on CPU;
pass --big for a ~100M-param run (use a TPU pod or be patient).

  PYTHONPATH=src python examples/train_small.py --steps 60
"""
import argparse
import dataclasses

from repro.configs import get_smoke_config
from repro.launch import train as train_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--big", action="store_true")
    args = ap.parse_args()

    import sys
    argv = ["train", "--arch", "llama3-8b", "--smoke",
            "--steps", str(args.steps), "--batch", "8", "--seq", "128",
            "--log-every", "10", "--ckpt-dir", "/tmp/repro_ckpt"]
    if args.big:
        # ~100M params: widen the smoke config via env-free override
        import repro.configs.llama3_8b as l3
        l3.SMOKE = dataclasses.replace(
            l3.SMOKE, num_layers=8, d_model=768, num_heads=12,
            num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
            vocab_pad_mult=128)
    sys.argv = argv
    train_mod.main()


if __name__ == "__main__":
    main()
