"""End-to-end driver (the paper's kind = inference serving): serve a small
model with batched requests through the continuous-batching engine while
the aging-aware core manager governs the host CPU, then replay the SAME
workload shape at cluster scale in the simulator and report the paper's
headline metrics.

  PYTHONPATH=src python examples/serve_cluster.py
"""
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import Model
from repro.serving.engine import InferenceEngine
from repro.sim import ExperimentConfig, carbon_comparison, run_policy_sweep


def serve_demo() -> None:
    print("=== serving demo (llama3-8b reduced config) ===")
    cfg = get_smoke_config("llama3-8b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, max_batch=4, max_len=96,
                             policy="proposed", num_host_cores=16)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for _ in range(12):
        engine.submit(rng.integers(0, cfg.vocab_size, 24).tolist(),
                      max_new_tokens=12)
    engine.run_until_drained()
    dt = time.time() - t0
    print(f"12 requests x 12 tokens in {dt:.2f}s "
          f"({144/dt:,.1f} tok/s)")
    rep = engine.host_cpu_report()
    print(f"host CPU: active {rep['active_cores']}/16 cores, "
          f"{rep['assigns']} CPU tasks routed through Algorithm 1\n")


def cluster_demo() -> None:
    print("=== cluster simulation (22 machines, policy x scenario) ===")
    res = run_policy_sweep(
        ExperimentConfig(num_cores=40, rate_rps=60, duration_s=60, seed=0),
        policies=("linux", "least-aged", "proposed"),
        scenarios=("conversation-poisson", "conversation-mmpp"))
    for (policy, scenario), m in res.items():
        print(f"{policy:10s} {scenario:24s} "
              f"deg_p99={m.mean_degradation_percentiles[99]:.5f} "
              f"idle_p90={m.idle_norm_percentiles[90]:+.3f} "
              f"lat_p99={m.p99_latency_s:.1f}s")
    sc = "conversation-poisson"
    est = carbon_comparison(res[("linux", sc)], res[("proposed", sc)], 99)
    print(f"\nestimated yearly CPU-embodied carbon reduction (p99, {sc}): "
          f"{100*est.reduction_frac:.2f}%  (paper: 37.67%)")


def routing_demo() -> None:
    print("\n=== cluster-level routing (fleet aging imbalance) ===")
    cfg = ExperimentConfig(num_cores=40, rate_rps=60, duration_s=60, seed=0)
    res = run_policy_sweep(cfg, policies=("proposed",),
                           routers=("jsq", "least-aged-cpu",
                                    "carbon-greedy"))
    for (policy, router), m in res.items():
        print(f"{router:16s} fleet_deg_cv={m.fleet_degradation_cv:.4f} "
              f"fleet_yearly={m.fleet_yearly_kgco2eq:7.1f} kgCO2eq "
              f"lat_p99={m.p99_latency_s:.1f}s")


if __name__ == "__main__":
    serve_demo()
    cluster_demo()
    routing_demo()
