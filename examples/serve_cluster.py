"""End-to-end driver (the paper's kind = inference serving): serve a small
model with batched requests through the continuous-batching engine while
the aging-aware core manager governs the host CPU, then replay the SAME
workload shape at cluster scale in the simulator and report the paper's
headline metrics.

  PYTHONPATH=src python examples/serve_cluster.py [--metrics-port PORT]

With `--metrics-port`, the serving demo additionally exposes the
engine's live Prometheus-style snapshot at
`http://127.0.0.1:PORT/metrics` while it drains — the same metrics
surface the simulator exports (`repro.telemetry.prometheus_text`),
which is what lets a simulator run shadow a live engine as a digital
twin.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import Model
from repro.serving.engine import InferenceEngine
from repro.sim import ExperimentConfig, carbon_comparison, run_policy_sweep
from repro.telemetry import TelemetryHub, start_metrics_server


def serve_demo(metrics_port: int | None = None) -> None:
    print("=== serving demo (llama3-8b reduced config) ===")
    cfg = get_smoke_config("llama3-8b")
    model = Model(cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(model, params, max_batch=4, max_len=96,
                             policy="proposed", num_host_cores=16,
                             telemetry=TelemetryHub())
    server = None
    if metrics_port is not None:
        server = start_metrics_server(engine.prometheus_text,
                                      port=metrics_port)
        print(f"metrics endpoint: "
              f"http://127.0.0.1:{server.server_port}/metrics")
    rng = np.random.default_rng(0)
    t0 = time.time()
    for _ in range(12):
        engine.submit(rng.integers(0, cfg.vocab_size, 24).tolist(),
                      max_new_tokens=12)
    engine.run_until_drained()
    dt = time.time() - t0
    print(f"12 requests x 12 tokens in {dt:.2f}s "
          f"({144/dt:,.1f} tok/s)")
    rep = engine.host_cpu_report()
    print(f"host CPU: active {rep['active_cores']}/16 cores, "
          f"{rep['assigns']} CPU tasks routed through Algorithm 1")
    snapshot = engine.prometheus_text()
    head = [ln for ln in snapshot.splitlines()
            if not ln.startswith("#")][:6]
    print("prometheus snapshot (first lines):")
    for ln in head:
        print(f"  {ln}")
    if server is not None:
        server.shutdown()
    print()


def cluster_demo() -> None:
    print("=== cluster simulation (22 machines, policy x scenario) ===")
    res = run_policy_sweep(
        ExperimentConfig(num_cores=40, rate_rps=60, duration_s=60, seed=0),
        policies=("linux", "least-aged", "proposed"),
        scenarios=("conversation-poisson", "conversation-mmpp"))
    for (policy, scenario), m in res.items():
        print(f"{policy:10s} {scenario:24s} "
              f"deg_p99={m.mean_degradation_percentiles[99]:.5f} "
              f"idle_p90={m.idle_norm_percentiles[90]:+.3f} "
              f"lat_p99={m.p99_latency_s:.1f}s")
    sc = "conversation-poisson"
    est = carbon_comparison(res[("linux", sc)], res[("proposed", sc)], 99)
    print(f"\nestimated yearly CPU-embodied carbon reduction (p99, {sc}): "
          f"{100*est.reduction_frac:.2f}%  (paper: 37.67%)")


def routing_demo() -> None:
    print("\n=== cluster-level routing (fleet aging imbalance) ===")
    cfg = ExperimentConfig(num_cores=40, rate_rps=60, duration_s=60, seed=0)
    res = run_policy_sweep(cfg, policies=("proposed",),
                           routers=("jsq", "least-aged-cpu",
                                    "carbon-greedy"))
    for (policy, router), m in res.items():
        print(f"{router:16s} fleet_deg_cv={m.fleet_degradation_cv:.4f} "
              f"fleet_yearly={m.fleet_yearly_kgco2eq:7.1f} kgCO2eq "
              f"lat_p99={m.p99_latency_s:.1f}s")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="expose the engine's Prometheus-style snapshot "
                    "at /metrics on this port during the serving demo "
                    "(0 = ephemeral)")
    args = ap.parse_args()
    serve_demo(metrics_port=args.metrics_port)
    cluster_demo()
    routing_demo()
