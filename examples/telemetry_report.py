"""Render an aging-timeline + carbon-window report from an exported
telemetry run.

Run an experiment with telemetry export first, then point this script
at the export directory it printed:

  PYTHONPATH=src python examples/carbon_report.py --duration 30 \
      --telemetry /tmp/tel
  PYTHONPATH=src python examples/telemetry_report.py \
      /tmp/tel/proposed-<fingerprint>

The report reads the JSONL event stream (`events.jsonl`) and the
series/timeline arrays (`series.npz`) and prints:

  * per-phase runner wall times and event-loop throughput,
  * the event-kind census with cause attribution (how many gates /
    wakes were plain policy decisions vs carbon-aware reshaping, how
    many wake-ups the dirty-hour guard deferred),
  * per-core gated-span statistics reconstructed from gate -> wake
    pairs (the Perfetto view, in text),
  * the fleet aging timeline (frequency spread over time), and
  * the per-window power / intensity / operational-carbon series.

Load `trace.json` in https://ui.perfetto.dev for the interactive
per-core span view of the same run.
"""
from __future__ import annotations

import argparse
import collections
import json
import os

import numpy as np

from repro.telemetry import read_jsonl


def _phase_table(meta: dict) -> None:
    gauges = meta.get("gauges", {})
    phases = {k.removeprefix("phase/").removesuffix("_wall_s"): v
              for k, v in gauges.items()
              if k.startswith("phase/") and k.endswith("_wall_s")}
    if phases:
        print("runner phases:")
        for name, wall in sorted(phases.items(), key=lambda kv: -kv[1]):
            print(f"  {name:14s} {wall:8.3f} s")
    eps = gauges.get("events_per_sec")
    if eps is not None:
        print(f"event loop: {gauges.get('events_processed', 0):,.0f} "
              f"events at {eps:,.0f} ev/s")


def _event_census(events: list[dict]) -> None:
    kinds = collections.Counter(e["kind"] for e in events)
    print("\nevent census:")
    for kind, n in kinds.most_common():
        print(f"  {kind:16s} {n:7d}")
    causes = collections.Counter(
        (e["kind"], e.get("cause", "-")) for e in events
        if e["kind"] in ("gate", "wake", "carbon_deferral"))
    if causes:
        print("cause attribution:")
        for (kind, cause), n in sorted(causes.items()):
            print(f"  {kind:16s} {cause:24s} {n:7d}")
    deferred = sum(e.get("deferred", 0) for e in events
                   if e["kind"] == "carbon_deferral")
    if deferred:
        print(f"  wake-ups deferred by the dirty-hour guard: {deferred}")


def _gated_spans(events: list[dict], t_end: float) -> None:
    open_gate: dict[tuple[int, int], float] = {}
    spans: list[float] = []
    for e in events:
        key = (e.get("machine", 0), e.get("core", -1))
        if e["kind"] == "gate":
            open_gate[key] = e["t"]
        elif e["kind"] == "wake":
            t0 = open_gate.pop(key, None)
            if t0 is not None:
                spans.append(e["t"] - t0)
    still_open = len(open_gate)
    spans.extend(t_end - t0 for t0 in open_gate.values())
    if not spans:
        print("\nno gated spans recorded")
        return
    a = np.asarray(spans)
    print(f"\ngated spans: {len(spans)} "
          f"({still_open} still gated at horizon) — "
          f"mean {a.mean():.2f} s, p50 {np.percentile(a, 50):.2f} s, "
          f"max {a.max():.2f} s")


def _aging_timelines(npz) -> None:
    machines = sorted(
        {k.split("/")[1] for k in npz.files
         if k.startswith("timeline/m") and k.endswith("/freq/values")},
        key=lambda m: int(m[1:]))
    rows = []
    for m in machines:
        t = npz[f"timeline/{m}/freq/t"]
        v = npz[f"timeline/{m}/freq/values"]
        if len(t) == 0:
            continue
        last = v[-1]
        rows.append((m, float(t[-1]), float(last.mean()),
                     float(last.min()), float(last.max())))
    if not rows:
        print("\nno aging timelines recorded (timeline_every too large?)")
        return
    print("\nper-machine settled frequency at the last sample "
          "(nominal 1.0):")
    print(f"  {'machine':8s} {'t':>8s} {'mean':>8s} {'min':>8s} "
          f"{'max':>8s}")
    for m, t, mean, lo, hi in rows:
        print(f"  {m:8s} {t:8.1f} {mean:8.5f} {lo:8.5f} {hi:8.5f}")


def _carbon_windows(npz) -> None:
    key = "timeline/fleet/carbon_windows"
    if f"{key}/t" not in npz.files:
        print("\nno fleet carbon windows recorded")
        return
    t = npz[f"{key}/t"]
    v = npz[f"{key}/values"]     # (W, 5): window_s, W, kWh, g/kWh, g
    if len(t) == 0:
        return
    print(f"\nfleet carbon windows ({len(t)} windows of "
          f"{v[0, 0]:.1f} s):")
    print(f"  {'t_start':>8s} {'power_W':>9s} {'kWh':>10s} "
          f"{'gCO2/kWh':>9s} {'op_g':>9s}")
    idx = np.linspace(0, len(t) - 1, min(len(t), 8)).astype(int)
    for i in idx:
        print(f"  {t[i]:8.1f} {v[i, 1]:9.0f} {v[i, 2]:10.6f} "
              f"{v[i, 3]:9.1f} {v[i, 4]:9.3f}")
    print(f"  total operational over horizon: {v[:, 4].sum():.2f} gCO2eq")


def main() -> None:
    ap = argparse.ArgumentParser(
        description="render a text report from a telemetry export "
        "directory (events.jsonl + series.npz)")
    ap.add_argument("export_dir", help="directory written by "
                    "`export_run` / a --telemetry DIR run")
    args = ap.parse_args()

    events_path = os.path.join(args.export_dir, "events.jsonl")
    npz_path = os.path.join(args.export_dir, "series.npz")
    meta, events = read_jsonl(events_path)
    t_end = max((e["t"] for e in events), default=0.0)

    print(f"telemetry report: {args.export_dir}")
    print(f"{meta.get('events', len(events))} events retained "
          f"({meta.get('events_dropped', 0)} dropped), "
          f"{len(meta.get('series', {}))} series, "
          f"{len(meta.get('timelines', {}))} timelines\n")
    _phase_table(meta)
    _event_census(events)
    _gated_spans(events, t_end)
    with np.load(npz_path) as npz:
        _aging_timelines(npz)
        _carbon_windows(npz)
    trace = os.path.join(args.export_dir, "trace.json")
    if os.path.exists(trace):
        print(f"\ninteractive spans: load {trace} in "
              f"https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
