"""Quickstart: the paper's aging-aware CPU core management in 60 lines.

Runs one server CPU (40 cores) under a bursty inference load with the
proposed technique vs the linux baseline, and prints the aging outcome
plus the embodied-carbon estimate.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import CoreManager, carbon

HOURS = 6
RATE = 3          # mean concurrent tasks per second


def simulate(policy: str) -> CoreManager:
    mgr = CoreManager(num_cores=40, policy=policy,
                      rng=np.random.default_rng(0), idling_period_s=1.0)
    rng = np.random.default_rng(1)
    task_id, t = 0, 0.0
    while t < HOURS * 3600:
        # Poisson burst of CPU inference tasks (submit/iteration/memory ops)
        for _ in range(rng.poisson(RATE)):
            mgr.assign(task_id, t)
            mgr.release(task_id, t + rng.uniform(0.005, 0.03))
            task_id += 1
        t += 1.0
        mgr.periodic(t)          # Algorithm 2: Selective Core Idling
    mgr.settle_all(HOURS * 3600)
    return mgr


def main() -> None:
    results = {}
    for policy in ("linux", "proposed"):
        mgr = simulate(policy)
        deg = mgr.mean_frequency_degradation()
        results[policy] = deg
        active = int((mgr.c_state == 0).sum())
        print(f"{policy:10s} mean_freq_degradation={deg:.5f} "
              f"freq_cv={mgr.frequency_cv():.4f} active_cores={active}/40")

    est = carbon.estimate(results["linux"], results["proposed"])
    print(f"\nCPU lifetime extension: {est.extension_factor:.2f}x "
          f"({est.extended_life_years:.1f} years)")
    print(f"Yearly CPU embodied carbon: "
          f"{est.baseline_yearly_kgco2eq:.1f} -> {est.yearly_kgco2eq:.1f} "
          f"kgCO2eq  ({100*est.reduction_frac:.1f}% reduction)")


if __name__ == "__main__":
    main()
