"""Quickstart: the paper's aging-aware CPU core management in 60 lines.

Runs one server CPU (40 cores) under a bursty inference load — drawn
from the pluggable workload-scenario registry (`repro.workloads`) — with
the proposed technique vs the linux baseline, and prints the aging
outcome plus the embodied-carbon estimate.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.carbon import get_carbon_model
from repro.core import CoreManager
from repro.workloads import get_scenario

HOURS = 6
RATE = 3          # mean requests (-> CPU task bursts) per second
SCENARIO = "conversation-mmpp"   # try conversation-diurnal, code-poisson...


def simulate(policy: str) -> CoreManager:
    mgr = CoreManager(num_cores=40, policy=policy,
                      rng=np.random.default_rng(0), idling_period_s=1.0)
    # One request stream, shared by both policies (seeded): each request
    # lands on the host CPU as one short inference task. Merge assigns,
    # releases and periodic ticks into one time-ordered event stream —
    # the manager requires non-decreasing timestamps.
    requests = get_scenario(SCENARIO).generate(
        rate_rps=RATE, duration_s=HOURS * 3600, seed=1)
    durations = np.random.default_rng(2).uniform(0.005, 0.03,
                                                 size=len(requests))
    events = sorted(
        [(r.arrival_s + durations[tid], 0, tid)         # release
         for tid, r in enumerate(requests)]
        + [(r.arrival_s, 1, tid) for tid, r in enumerate(requests)]
        + [(float(k), 2, -1) for k in range(1, HOURS * 3600 + 1)])
    for t, kind, tid in events:
        if kind == 1:
            mgr.assign(tid, t)
        elif kind == 0:
            mgr.release(tid, t)
        else:
            mgr.periodic(t)      # Algorithm 2: Selective Core Idling
    mgr.settle_all(HOURS * 3600)
    return mgr


def main() -> None:
    results = {}
    for policy in ("linux", "proposed"):
        mgr = simulate(policy)
        deg = mgr.mean_frequency_degradation()
        results[policy] = deg
        active = int((mgr.c_state == 0).sum())
        print(f"{policy:10s} mean_freq_degradation={deg:.5f} "
              f"freq_cv={mgr.frequency_cv():.4f} active_cores={active}/40")

    est = get_carbon_model("linear-extension").lifetime(
        results["linux"], results["proposed"])
    print(f"\nCPU lifetime extension: {est.extension_factor:.2f}x "
          f"({est.extended_life_years:.1f} years)")
    print(f"Yearly CPU embodied carbon: "
          f"{est.baseline_yearly_kgco2eq:.1f} -> {est.yearly_kgco2eq:.1f} "
          f"kgCO2eq  ({100*est.reduction_frac:.1f}% reduction)")


if __name__ == "__main__":
    main()
